"""Integration: DWN end-to-end training, PTQ/FT protocol, hard/soft parity."""

import numpy as np
import jax
import pytest

from repro.core import (JSC_PRESETS, train_dwn, freeze, eval_accuracy_hard,
                        ptq_bitwidth_search)
from repro.core.training import eval_soft
from repro.core.warmstart import warmstart_dwn
from repro.data.jsc import load_jsc


@pytest.fixture(scope="module")
def small_run():
    data = load_jsc(4000, 1000, seed=0)
    cfg = JSC_PRESETS["sm-50"]
    params, buffers = warmstart_dwn(jax.random.PRNGKey(0), cfg,
                                    data.x_train, data.y_train)
    res = train_dwn(cfg, data, epochs=4, batch=128, lr=1e-3,
                    params=params, buffers=buffers, verbose=False)
    return data, cfg, res


def test_training_beats_chance_and_improves(small_run):
    data, cfg, res = small_run
    assert res.history[0]["loss"] > res.history[-1]["loss"] * 0.95
    assert res.soft_test_acc > 0.40          # >> 20% chance


def test_soft_hard_parity(small_run):
    """Training-path accuracy == frozen hardware-path accuracy (the
    forward is already binarized, so freeze must be bit-exact)."""
    data, cfg, res = small_run
    fr = freeze(res.params, res.buffers, cfg)
    hard = eval_accuracy_hard(fr, data.x_test, data.y_test)
    soft = eval_soft(res.params, res.buffers, cfg, data.x_test, data.y_test)
    assert abs(hard - soft) < 1e-6


def test_ptq_protocol_monotone(small_run):
    """PTQ sweep: accuracy at high bit-width ~= float accuracy; the search
    returns the smallest width meeting baseline."""
    data, cfg, res = small_run
    base = res.soft_test_acc
    ptq = ptq_bitwidth_search(res.params, res.buffers, cfg, data,
                              baseline_acc=base, max_frac=10, verbose=False)
    widths = [w for w, _ in ptq.sweep]
    assert widths == sorted(widths, reverse=True)
    # highest-width PTQ must be within a point of float accuracy
    assert abs(ptq.sweep[0][1] - base) < 0.02
    assert ptq.total_bits <= 11


def test_frozen_verilog_roundtrip(small_run):
    """The emitted Verilog's LUT INITs and wiring match the frozen model:
    simulate the netlist semantics in numpy and compare predictions."""
    data, cfg, res = small_run
    fr = freeze(res.params, res.buffers, cfg, input_frac_bits=6)
    from repro.hw.verilog import emit_dwn
    src = emit_dwn(fr, name="sim")
    assert "INIT_0_0" in src
    # numpy re-simulation of the frozen semantics
    from repro.core.model import apply_hard
    from repro.core.classifier import predict
    import jax.numpy as jnp
    counts = apply_hard(fr, jnp.asarray(data.x_test[:64]))
    pred = np.asarray(predict(counts))
    assert pred.shape == (64,)
    assert set(np.unique(pred)) <= set(range(5))
