"""Multi-layer DWN stacks ([13] allows them; the paper's JSC models use a
single LUT layer) — framework-level support check."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import JSC_PRESETS, train_dwn, freeze, eval_accuracy_hard
from repro.core.model import DWNConfig, init_dwn, apply_train, apply_hard
from repro.data.jsc import load_jsc


def test_two_layer_forward_and_freeze():
    cfg = DWNConfig(lut_counts=(120, 50))
    data = load_jsc(1024, 256)
    params, buffers = init_dwn(jax.random.PRNGKey(0), cfg, data.x_train)
    x = jnp.asarray(data.x_train[:32])
    logits = apply_train(params, buffers, cfg, x)
    assert logits.shape == (32, 5)
    assert np.isfinite(np.asarray(logits)).all()
    # layer 1 candidates = thermometer bits; layer 2 candidates = layer-1 out
    assert params["layers"][0]["scores"].shape[-1] == 16 * 200
    assert params["layers"][1]["scores"].shape[-1] == 120
    fr = freeze(params, buffers, cfg)
    counts = apply_hard(fr, x)
    assert counts.shape == (32, 5)


def test_two_layer_trains():
    cfg = DWNConfig(lut_counts=(80, 50))
    data = load_jsc(2000, 500)
    res = train_dwn(cfg, data, epochs=3, batch=128, lr=3e-3, verbose=False)
    fr = freeze(res.params, res.buffers, cfg)
    acc = eval_accuracy_hard(fr, data.x_test, data.y_test)
    assert acc > 0.3                     # well above 20% chance in 3 epochs
    assert np.isfinite(res.history[-1]["loss"])
