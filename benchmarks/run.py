"""Benchmark harness: every benchmark family behind one command —
paper tables/figures, roofline, kernels, serving, and the sweep smoke.

Usage: PYTHONPATH=src python -m benchmarks.run [--only table1,fig5]
Each benchmark prints ``name,us_per_call,derived`` CSV rows followed by its
markdown table.
"""

import argparse
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    from . import (table1_hardware, table2_literature, table3_quantization,
                   cosim_smoke, fig2_encoding, fig5_breakdown, fig6_pareto,
                   roofline_report, kernels_bench, load_harness, serve_bench,
                   sweep_smoke, train_bench)
    benches = {
        "table1": table1_hardware.run,
        "table2": table2_literature.run,
        "table3": table3_quantization.run,
        "fig2": fig2_encoding.run,
        "fig5": fig5_breakdown.run,
        "fig6": fig6_pareto.run,
        "roofline": roofline_report.run,
        "kernels": kernels_bench.run,
        "serve": serve_bench.run,
        "load": load_harness.run,
        "sweep": sweep_smoke.run,
        "cosim": cosim_smoke.run,
        "train": train_bench.run,
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"\n{'=' * 70}\n== {name}\n{'=' * 70}", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    print(f"\nbenchmarks done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
