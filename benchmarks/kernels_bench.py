"""Kernel micro-benchmarks (interpret mode on CPU; wall time is the CPU
emulation, the derived column carries the TPU-relevant byte/FLOP counts).

Also quantifies the fused kernel's HBM-traffic saving vs the staged
pipeline — the paper's "encoding dominates" insight as bytes.
"""

from .common import csv_row, Timer


def run():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.thermometer import ops as th_ops
    from repro.kernels.lut_eval import ops as lut_ops
    from repro.kernels.popcount import ops as pc_ops
    from repro.kernels.fused import ops as f_ops

    B, F, T, m, n, C = 1024, 16, 200, 2400, 6, 5
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (B, F), minval=-1, maxval=1)
    th = jnp.sort(jax.random.uniform(key, (F, T), minval=-1, maxval=1), 1)
    mapping = jax.random.randint(key, (m, n), 0, F * T)
    tables = jax.random.randint(key, (m, 64), 0, 2).astype(jnp.float32)

    # staged pipeline
    with Timer() as t1:
        bits = th_ops.encode(x, th, interpret=True)
        bits.block_until_ready()
    with Timer() as t2:
        out = lut_ops.evaluate(bits, mapping, tables, interpret=True)
        out.block_until_ready()
    with Timer() as t3:
        counts, idx = pc_ops.classify(out, C, interpret=True)
        counts.block_until_ready()
    with Timer() as t4:
        fused = f_ops.forward(x, th, mapping, tables, C, interpret=True)
        fused.block_until_ready()
    np.testing.assert_allclose(np.asarray(fused), np.asarray(counts),
                               atol=1e-4)

    # HBM traffic model (bf16 bits): staged writes + re-reads the unary
    # blow-up; fused keeps it in VMEM.
    bits_bytes = B * F * T * 2
    staged = (B * F * 4                       # read x
              + 2 * bits_bytes                # write + read bits
              + m * 64 * 4 + B * m * 4 * 2    # tables + lut out w/r
              + B * C * 4)
    fused_b = B * F * 4 + m * 64 * 4 + B * C * 4
    csv_row("kernels/thermometer", t1.us, f"bits_bytes={bits_bytes}")
    csv_row("kernels/lut_eval", t2.us, f"m={m}")
    csv_row("kernels/popcount", t3.us, f"classes={C}")
    csv_row("kernels/fused", t4.us,
            f"staged_hbm={staged};fused_hbm={fused_b};"
            f"saving={staged / fused_b:.1f}x")
    print(f"\nfused vs staged modeled HBM traffic: {staged / fused_b:.1f}x "
          f"({staged / 1e6:.1f} MB -> {fused_b / 1e6:.2f} MB per "
          f"{B}-sample batch)")


if __name__ == "__main__":
    run()
