"""Kernel micro-benchmarks (interpret mode on CPU; wall time is the CPU
emulation, the derived column carries the TPU-relevant byte/FLOP counts).

Two datapaths at the paper's lg-2400 scale (B=1024, F=16, T=200, m=2400):

* float: every bit is a float32 — thermometer -> one-hot-matmul LUT eval
  -> popcount, staged through HBM, plus the float fused kernel;
* packed: every bit lives in uint32 words (32/word) — packed encode ->
  shift/AND LUT eval -> SWAR popcount, plus the fused packed kernel that
  keeps the words VMEM-resident end-to-end, in both its ``packed``
  (full bit tensor) and ``batch-major`` (direct-wire) variants.

Timings (warmed, so compile time is excluded) and the packed-vs-float
speedups are written to ``BENCH_kernels.json`` at the repo root (one
record per run, overwritten).

``--smoke-bm`` runs the batch-major bit-exactness smoke instead (all
three JSC preset widths + a ragged batch), used as a fast CI gate.
"""

import json
import sys

from .common import csv_row, Timer, ROOT

BENCH_JSON = ROOT / "BENCH_kernels.json"


def _timed(fn):
    """(us, result) of one warmed call: run once to compile, then time."""
    import jax
    jax.block_until_ready(fn())
    with Timer() as t:
        out = fn()
        jax.block_until_ready(out)
    return t.us, out


def smoke_bm():
    """Batch-major bit-exactness smoke: all three JSC preset LUT widths
    (plus a ragged, non-power-of-two batch) against the packed oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.autotune import FusedConfig
    from repro.kernels.fused import ops as f_ops

    F, T, n, C = 16, 200, 6, 5
    for m, B in ((50, 64), (360, 37), (2400, 128)):
        key = jax.random.PRNGKey(m)
        kx, kt, km, kl = jax.random.split(key, 4)
        x = jax.random.uniform(kx, (B, F), minval=-1, maxval=1)
        th = jnp.sort(jax.random.uniform(kt, (F, T), minval=-1, maxval=1), 1)
        mapping = jax.random.randint(km, (m, n), 0, F * T)
        tables = jax.random.randint(kl, (m, 64), 0, 2).astype(jnp.int32)
        ref_counts, ref_idx = f_ops.fused_dwn_packed_ref(
            x, th, [mapping], [tables], C)
        counts, idx = f_ops.forward_packed(
            x, th, mapping, tables, C, interpret=True,
            config=FusedConfig(variant="batch-major", block_b=64))
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(ref_counts))
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
        print(f"smoke-bm ok: m={m} B={B}")
    print("batch-major bit-exact on all preset widths")


def run():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.autotune import FusedConfig
    from repro.kernels.thermometer import ops as th_ops
    from repro.kernels.lut_eval import ops as lut_ops
    from repro.kernels.popcount import ops as pc_ops
    from repro.kernels.fused import ops as f_ops

    B, F, T, m, n, C = 1024, 16, 200, 2400, 6, 5
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (B, F), minval=-1, maxval=1)
    th = jnp.sort(jax.random.uniform(key, (F, T), minval=-1, maxval=1), 1)
    mapping = jax.random.randint(key, (m, n), 0, F * T)
    tables_f = jax.random.randint(key, (m, 64), 0, 2).astype(jnp.float32)
    tables_i = tables_f.astype(jnp.int32)

    # ---- float staged pipeline ------------------------------------------
    t_enc, bits = _timed(lambda: th_ops.encode(x, th, interpret=True))
    t_lut, out = _timed(lambda: lut_ops.evaluate(bits, mapping, tables_f,
                                                 interpret=True))
    t_pop, counts = _timed(lambda: pc_ops.classify(out, C,
                                                   interpret=True)[0])
    t_fused_f, fused_f = _timed(lambda: f_ops.forward(x, th, mapping,
                                                      tables_f, C,
                                                      interpret=True))
    np.testing.assert_allclose(np.asarray(fused_f[0]), np.asarray(counts),
                               atol=1e-4)

    # ---- packed pipeline -------------------------------------------------
    t_enc_p, pwords = _timed(
        lambda: th_ops.encode_packed(x, th, interpret=True).words)
    from repro.core.bitpack import PackedBits
    packed = PackedBits(pwords, F * T)
    t_lut_p, powords = _timed(lambda: lut_ops.evaluate_packed(
        packed, mapping, tables_i, interpret=True).words)
    pout = PackedBits(powords, m)
    t_pop_p, _ = _timed(lambda: pc_ops.classify_packed(pout, C,
                                                       interpret=True)[0])
    t_fused_p, fused_p = _timed(lambda: f_ops.forward_packed(
        x, th, mapping, tables_i, C, interpret=True)[0])
    np.testing.assert_array_equal(np.asarray(fused_p), np.asarray(counts))

    # batch-major variant at the same scale (rows-per-step = 256, the
    # default the autotuner sweeps around)
    fwd_bm = f_ops.make_forward_packed(
        th, mapping, tables_i, C, interpret=True,
        config=FusedConfig(variant="batch-major", block_b=256))
    t_fused_bm, fused_bm = _timed(lambda: fwd_bm(x)[0])
    np.testing.assert_array_equal(np.asarray(fused_bm), np.asarray(counts))

    # ---- HBM traffic model ----------------------------------------------
    # float staged writes + re-reads the unary blow-up at 4 B/bit; packed
    # moves the identical bits at 1/32 B/bit; fused keeps them in VMEM.
    bits_f32 = B * F * T * 4
    bits_pack = B * F * T // 8
    staged_f = (B * F * 4 + 2 * bits_f32 + m * 64 * 4 + B * m * 4 * 2
                + B * C * 4)
    staged_p = (B * F * 4 + 2 * bits_pack + m * 64 * 4 + B * (m // 8) * 2
                + B * C * 4)
    fused_b = B * F * 4 + m * 64 * 4 + B * C * 4

    staged_total_f = t_enc + t_lut + t_pop
    csv_row("kernels/thermometer", t_enc, f"bits_bytes={bits_f32}")
    csv_row("kernels/thermometer_packed", t_enc_p,
            f"bits_bytes={bits_pack};vs_float={t_enc / t_enc_p:.1f}x")
    csv_row("kernels/lut_eval", t_lut, f"m={m}")
    csv_row("kernels/lut_eval_packed", t_lut_p,
            f"m={m};vs_float={t_lut / t_lut_p:.1f}x")
    csv_row("kernels/popcount", t_pop, f"classes={C}")
    csv_row("kernels/popcount_packed", t_pop_p,
            f"classes={C};vs_float={t_pop / t_pop_p:.1f}x")
    csv_row("kernels/fused", t_fused_f,
            f"staged_hbm={staged_f};fused_hbm={fused_b};"
            f"saving={staged_f / fused_b:.1f}x")
    csv_row("kernels/fused_packed", t_fused_p,
            f"vs_float_staged={staged_total_f / t_fused_p:.1f}x;"
            f"vs_float_fused={t_fused_f / t_fused_p:.1f}x")
    csv_row("kernels/fused_batch_major", t_fused_bm,
            f"vs_packed={t_fused_p / t_fused_bm:.1f}x;"
            f"vs_float_fused={t_fused_f / t_fused_bm:.1f}x")

    record = {
        "scale": {"B": B, "F": F, "T": T, "m": m, "classes": C},
        "float_us": {"encode": round(t_enc, 1), "lut_eval": round(t_lut, 1),
                     "popcount": round(t_pop, 1),
                     "staged_total": round(staged_total_f, 1),
                     "fused": round(t_fused_f, 1)},
        "packed_us": {"encode": round(t_enc_p, 1),
                      "lut_eval": round(t_lut_p, 1),
                      "popcount": round(t_pop_p, 1),
                      "fused": round(t_fused_p, 1),
                      "fused_batch_major": round(t_fused_bm, 1)},
        "speedup": {
            "fused_packed_vs_float_staged":
                round(staged_total_f / t_fused_p, 2),
            "fused_packed_vs_float_fused": round(t_fused_f / t_fused_p, 2),
            "fused_batch_major_vs_packed": round(t_fused_p / t_fused_bm, 2),
            "encode_packed_vs_float": round(t_enc / t_enc_p, 2),
        },
        "hbm_model_bytes": {"float_staged": staged_f,
                            "packed_staged": staged_p, "fused": fused_b},
        "bit_exact": True,
    }
    with open(BENCH_JSON, "w") as fh:
        json.dump(record, fh, indent=2)
    print(f"\npacked fused vs float staged pipeline: "
          f"{staged_total_f / t_fused_p:.1f}x wall-clock "
          f"({staged_total_f / 1e3:.1f} ms -> {t_fused_p / 1e3:.2f} ms per "
          f"{B}-sample batch); batch-major fused {t_fused_bm / 1e3:.2f} ms; "
          f"bit widths: {bits_f32 / 1e6:.1f} MB float "
          f"-> {bits_pack / 1e6:.2f} MB packed; written {BENCH_JSON.name}")


if __name__ == "__main__":
    if "--smoke-bm" in sys.argv[1:]:
        smoke_bm()
    else:
        run()
