"""Serving benchmark: throughput + latency per backend per serving preset.

Serves an identical, seeded request stream through every registered DWN
datapath backend on each serving preset (JSC sm/md/lg plus the MNIST
sm/md rows — synthetic-fallback data in CI) via the ServingEngine,
and records throughput and p50/p99/p999 latency plus shed-rate and
queue-depth fields to ``BENCH_serve.json`` at the repo root — the
serving-level companion of ``BENCH_kernels.json``.  Rows share their
metric names with the open-loop latency–throughput curve that
``benchmarks/load_harness.py`` stores under ``"curve"`` in the same file
(this bench preserves that section when it rewrites the record; the
closed-loop rows here never shed, so their ``shed_rate`` is 0 by
construction).

The engine starts with ``backend="auto"`` and autotuning on, so the
fused-packed rows run the *tuned* kernel config for each bucket (variant
+ rows-per-step from the persistent autotune cache, docs/autotune.md);
the chosen config is recorded per cell.

Per backend the engine first serves one warmup request so the
per-(backend, bucket) compile is excluded from the timed stream, matching
how a long-running server amortizes compiles.  Wall times on CPU are the
interpret-mode emulation for the Pallas backend; the cross-backend
*ordering* (packed vs float) is the TPU-relevant signal.

Regression gate: every cell is compared against the committed
``BENCH_serve.json``; if any *previously-winning* backend regresses by
more than 15% throughput, the flagged cells are **re-measured once**
(fresh engine, same seeded stream) and the bench exits non-zero only if
the second pass confirms the drop — interpret-mode wall times on a
shared 2-core CI host jitter up to ~2x run-to-run, so a single slow pass
is evidence of a noisy neighbor, not a regression.  Both passes are
recorded in the ``regression`` block.  Set ``SERVE_BENCH_NO_GATE=1`` to
record without gating, e.g. when moving the baseline to new hardware.
"""

import json
import os
import time

from .common import csv_row, ROOT

BENCH_JSON = ROOT / "BENCH_serve.json"

PRESETS = ("dwn-jsc-sm", "dwn-jsc-md", "dwn-jsc-lg")
#: second-workload rows (repro.workloads: synthetic fallback in CI).
#: Recorded alongside the JSC rows but *never* gated — the regression
#: gate below is scoped to dwn-jsc-* so MNIST rows can't fail a build
#: while their baselines settle.
MNIST_PRESETS = ("dwn-mnist-sm", "dwn-mnist-md")
REQUESTS = 32
BATCH = 64
REGRESSION_PCT = 15.0


def _stream(engine, rng_seed=0):
    """Serve the seeded REQUESTS x BATCH stream; returns (thru, lat)."""
    import numpy as np
    from repro.serving.scheduler import latency_stats
    rng = np.random.default_rng(rng_seed)
    t0 = time.perf_counter()
    for _ in range(REQUESTS):
        engine.submit(engine.make_request(
            BATCH, seed=int(rng.integers(2**31))))
    done = engine.drain()
    wall = time.perf_counter() - t0
    served = sum(r.size for r in done)
    # compute_ms = datapath latency per step; queue wait is an
    # artifact of pre-submitting the whole stream
    lat = latency_stats(done)["compute_ms"]
    return round(served / wall, 1), lat


def _load_baseline():
    try:
        with open(BENCH_JSON) as fh:
            return json.load(fh)
    except (OSError, json.JSONDecodeError):
        return None


def _regression_block(record, baseline):
    """Compare each cell vs the committed record; flag >15% throughput
    drops of any previously-winning backend."""
    block = {"threshold_pct": REGRESSION_PCT, "cells": [], "failed": []}
    if not baseline:
        return block
    for preset, old in baseline.get("presets", {}).items():
        if not preset.startswith("dwn-jsc-"):
            # only the JSC rows gate; other workloads (MNIST, ...) are
            # recorded for tracking but never fail the build
            continue
        new = record["presets"].get(preset)
        old_backends = old.get("backends", {})
        if not new or not old_backends:
            continue
        winner = max(old_backends,
                     key=lambda b: old_backends[b].get(
                         "throughput_samples_per_s", 0.0))
        old_thru = old_backends[winner]["throughput_samples_per_s"]
        new_thru = new["backends"].get(winner, {}).get(
            "throughput_samples_per_s", 0.0)
        regressed = new_thru < old_thru * (1 - REGRESSION_PCT / 100)
        cell = {"preset": preset, "backend": winner,
                "baseline_throughput": old_thru,
                "throughput": new_thru,
                "delta_pct": round((new_thru / old_thru - 1) * 100, 1)
                if old_thru else 0.0,
                "regressed": regressed}
        block["cells"].append(cell)
        if regressed:
            block["failed"].append(f"{preset}/{winner}")
    return block


def _confirm_regressions(block) -> None:
    """Re-measure each flagged cell once and keep only confirmed drops.

    Wall-clock throughput on a shared CI host is noisy (interpret-mode
    cells jitter up to ~2x run-to-run); a single slow pass must not fail
    the build.  Each flagged (preset, winner-backend) cell gets one fresh
    engine + the same seeded stream; the cell stays failed only if the
    second pass *also* breaches the threshold.  Both passes land in the
    recorded cell (``throughput`` / ``confirm_throughput``).
    """
    from repro.serving import ServingEngine

    block["failed"] = []
    for cell in block["cells"]:
        if not cell["regressed"]:
            continue
        print(f"regression flagged for {cell['preset']}/{cell['backend']} "
              f"({cell['delta_pct']}%); re-measuring to confirm...")
        engine = ServingEngine(cell["preset"], max_bucket=BATCH,
                               min_bucket=8, n_train=2000, verify=True,
                               backend="auto", autotune=True)
        engine.use_backend(cell["backend"])
        engine.warmup(BATCH)
        thru, _ = _stream(engine)
        old_thru = cell["baseline_throughput"]
        confirmed = thru < old_thru * (1 - REGRESSION_PCT / 100)
        cell["confirm_throughput"] = thru
        cell["confirm_delta_pct"] = (round((thru / old_thru - 1) * 100, 1)
                                     if old_thru else 0.0)
        cell["regressed"] = confirmed
        if confirmed:
            block["failed"].append(f"{cell['preset']}/{cell['backend']}")
        else:
            print(f"  not confirmed: second pass {thru} vs baseline "
                  f"{old_thru} — treating first pass as noise")


def run():
    from repro.serving import ServingEngine, available_backends

    baseline = _load_baseline()
    record = {"stream": {"requests": REQUESTS, "batch": BATCH},
              "presets": {}}
    for preset in PRESETS + MNIST_PRESETS:
        # backend="auto" + autotune=True: startup tunes the fused kernel
        # per bucket and calibrates every bit-exact backend, so the
        # per-backend rows below all serve their steady-state best
        engine = ServingEngine(preset, max_bucket=BATCH, min_bucket=8,
                               n_train=2000, verify=True, backend="auto",
                               autotune=True)
        tuned = {int(b): cfg.to_dict()
                 for b, cfg in engine.tuned_configs.items()}
        per_backend = {}
        for backend in available_backends():
            engine.use_backend(backend)
            # compile the (backend, BATCH) bucket outside timing
            engine.warmup(BATCH)
            thru, lat = _stream(engine)
            per_backend[backend] = {
                "throughput_samples_per_s": thru,
                "latency_ms_p50": lat["p50"],
                "latency_ms_p99": lat["p99"],
                "latency_ms_p999": lat["p999"],
                "shed_rate": 0.0,
                "queue_depth_max_requests": REQUESTS,
            }
            if backend == "fused-packed":
                per_backend[backend]["config"] = tuned.get(BATCH)
            csv_row(f"serve/{preset}/{backend}",
                    lat["p50"] * 1e3,
                    f"thru={thru};p99_ms={lat['p99']}")
        # auto-select row: per-bucket calibration picks the fastest
        # bit-exact backend serving its tuned kernel config
        engine.use_backend("auto")
        engine.warmup(BATCH)
        thru, lat = _stream(engine)
        auto_row = {
            "throughput_samples_per_s": thru,
            "latency_ms_p50": lat["p50"],
            "latency_ms_p99": lat["p99"],
            "latency_ms_p999": lat["p999"],
            "shed_rate": 0.0,
            "queue_depth_max_requests": REQUESTS,
            "choice": dict(engine.auto.choice),
            "configs": {b: (cfg.to_dict() if cfg else None)
                        for b, cfg in engine.auto.configs.items()},
        }
        csv_row(f"serve/{preset}/auto", lat["p50"] * 1e3,
                f"thru={thru};choice={engine.auto.choice}")
        record["presets"][preset] = {
            "luts": engine.cfg.dwn_luts,
            "bit_exact_vs_oracle": engine.bit_exact,
            "autotune": tuned,
            "backends": per_backend,
            "auto": auto_row,
        }

    record["regression"] = _regression_block(record, baseline)
    if record["regression"]["failed"]:
        # flaky-host guard: a single slow pass needs a confirming second
        # measurement before it can fail the build
        _confirm_regressions(record["regression"])
    if baseline and "curve" in baseline:
        # the open-loop curve belongs to benchmarks/load_harness.py;
        # carry it through unchanged when this bench rewrites the record
        record["curve"] = baseline["curve"]
    with open(BENCH_JSON, "w") as fh:
        json.dump(record, fh, indent=2)
    n_presets = len(PRESETS) + len(MNIST_PRESETS)
    print(f"\nwritten {BENCH_JSON.name}: "
          f"{n_presets} presets x {len(record['presets'][PRESETS[0]]['backends'])} "
          f"backends, {REQUESTS}x{BATCH} samples each")
    failed = record["regression"]["failed"]
    if failed:
        msg = (f"serve bench regression gate: previously-winning backends "
               f"dropped >{REGRESSION_PCT:.0f}% throughput in both "
               f"measurement passes: {failed}")
        if os.environ.get("SERVE_BENCH_NO_GATE") == "1":
            print(f"WARNING (gate disabled): {msg}")
        else:
            print(f"ERROR: {msg}")
            raise SystemExit(1)


if __name__ == "__main__":
    run()
