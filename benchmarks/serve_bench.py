"""Serving benchmark: throughput + latency per backend per JSC preset.

Serves an identical, seeded request stream through every registered DWN
datapath backend on each serving preset (sm/md/lg) via the ServingEngine,
and records throughput and p50/p99 total latency to ``BENCH_serve.json``
at the repo root (one record per run, overwritten) — the serving-level
companion of ``BENCH_kernels.json``.

Per backend the engine first serves one warmup request so the
per-(backend, bucket) compile is excluded from the timed stream, matching
how a long-running server amortizes compiles.  Wall times on CPU are the
interpret-mode emulation for the Pallas backend; the cross-backend
*ordering* (packed vs float) is the TPU-relevant signal.
"""

import json
import time

from .common import csv_row, ROOT

BENCH_JSON = ROOT / "BENCH_serve.json"

PRESETS = ("dwn-jsc-sm", "dwn-jsc-md", "dwn-jsc-lg")
REQUESTS = 4
BATCH = 64


def run():
    import numpy as np
    from repro.serving import ServingEngine, available_backends
    from repro.serving.scheduler import latency_stats

    record = {"stream": {"requests": REQUESTS, "batch": BATCH},
              "presets": {}}
    for preset in PRESETS:
        engine = ServingEngine(preset, max_bucket=BATCH, min_bucket=8,
                               n_train=2000, verify=True)
        per_backend = {}
        for backend in available_backends():
            engine.use_backend(backend)
            # compile the (backend, BATCH) bucket outside timing
            engine.warmup(BATCH)
            rng = np.random.default_rng(0)
            t0 = time.perf_counter()
            for _ in range(REQUESTS):
                engine.submit(engine.make_request(
                    BATCH, seed=int(rng.integers(2**31))))
            done = engine.drain()
            wall = time.perf_counter() - t0
            served = sum(r.size for r in done)
            # compute_ms = datapath latency per step; queue wait is an
            # artifact of pre-submitting the whole stream
            lat = latency_stats(done)["compute_ms"]
            per_backend[backend] = {
                "throughput_samples_per_s": round(served / wall, 1),
                "latency_ms_p50": lat["p50"],
                "latency_ms_p99": lat["p99"],
            }
            csv_row(f"serve/{preset}/{backend}",
                    lat["p50"] * 1e3,
                    f"thru={per_backend[backend]['throughput_samples_per_s']}"
                    f";p99_ms={lat['p99']}")
        # auto-select row: per-bucket calibration picks the fastest
        # bit-exact backend (BENCH history shows the winner is
        # size-dependent: float-oracle on sm, packed paths on md/lg)
        engine.use_backend("auto")
        engine.warmup(BATCH)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for _ in range(REQUESTS):
            engine.submit(engine.make_request(
                BATCH, seed=int(rng.integers(2**31))))
        done = engine.drain()
        wall = time.perf_counter() - t0
        served = sum(r.size for r in done)
        lat = latency_stats(done)["compute_ms"]
        auto_row = {
            "throughput_samples_per_s": round(served / wall, 1),
            "latency_ms_p50": lat["p50"],
            "latency_ms_p99": lat["p99"],
            "choice": dict(engine.auto.choice),
        }
        csv_row(f"serve/{preset}/auto", lat["p50"] * 1e3,
                f"thru={auto_row['throughput_samples_per_s']}"
                f";choice={engine.auto.choice}")
        record["presets"][preset] = {
            "luts": engine.cfg.dwn_luts,
            "bit_exact_vs_oracle": engine.bit_exact,
            "backends": per_backend,
            "auto": auto_row,
        }

    with open(BENCH_JSON, "w") as fh:
        json.dump(record, fh, indent=2)
    print(f"\nwritten {BENCH_JSON.name}: "
          f"{len(PRESETS)} presets x {len(record['presets'][PRESETS[0]]['backends'])} "
          f"backends, {REQUESTS}x{BATCH} samples each")


if __name__ == "__main__":
    run()
