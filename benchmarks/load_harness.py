"""Open-loop load harness: the latency–throughput curve per preset.

Drives each serving preset from light load to past saturation with the
seeded Poisson load generator (``repro.launch.loadgen``) in **both**
serving modes — the continuous-batching async engine and the synchronous
submit/drain facade — and records, per offered-load level: achieved
throughput, p50/p99/p999 latency (measured from intended arrival time,
identically for both modes), shed rate, and queue depth.  The curve lands
in ``BENCH_serve.json`` under ``"curve"``, next to (not replacing) the
per-backend closed-loop rows that ``serve_bench`` owns.

Headline derived metric: ``speedup_vs_sync_at_equal_p99`` — the
continuous engine's sustained throughput at its highest sustainable
level, over the best sync-mode throughput whose p99 is no worse.  Under
overload the sync facade serves everything late (p99 grows with the
backlog) while SLO admission keeps the continuous path's served-request
p99 bounded by shedding, so sync only matches that p99 at a much lower
offered load.  "Highest sustainable" = the largest level that still
serves >= MIN_SUSTAINED_FRAC of offered samples.

Modes:
  full (default): 3 presets x 5 levels + a mixed sm+md multi-tenant run.
  --smoke / REPRO_LOAD_SMOKE=1: 1 preset x 2 levels, short streams — the
    CI configuration; the committed curve comes from a full run.

The >15% throughput regression gate for previously-winning per-backend
rows stays in ``serve_bench`` — this harness only *adds* the curve
section, so running it in CI after serve_bench reuses that gate
unchanged.  The smoke run still enforces the SLO invariant (no request
returned late without being marked shed) and the speedup floor
(``LOAD_HARNESS_NO_GATE=1`` to record without gating).
"""

import json
import os
import sys

from .common import ROOT

BENCH_JSON = ROOT / "BENCH_serve.json"

FULL_PRESETS = ("dwn-jsc-sm", "dwn-jsc-md", "dwn-jsc-lg")
FULL_LEVELS = (0.25, 0.5, 0.75, 1.0, 1.3)
SMOKE_PRESETS = ("dwn-jsc-sm",)
SMOKE_LEVELS = (0.25, 1.3)
FULL_DURATION_S = 3.0
SMOKE_DURATION_S = 1.5
DEADLINE_MS = 50.0
#: sizes straddle max_bucket so the stream exercises oversize chunking;
#: the upper end also keeps the request rate low enough that the
#: in-process producer never becomes the measured bottleneck
SIZES = "uniform:64:512"
MEAN_SIZE = (64 + 512) / 2
MAX_BUCKET = 256
CAPACITY_REQUESTS = 48
#: a level is "sustainable" if it serves at least this share of offered
MIN_SUSTAINED_FRAC = 0.9
SPEEDUP_FLOOR = 1.3


def _speedup_at_equal_p99(levels: list) -> dict:
    """continuous thru @ highest sustainable level vs best sync thru at
    <= that p99.  Returns the block stored next to the curve."""
    cont = [(lv["continuous"], lv) for lv in levels if "continuous" in lv]
    sync = [lv["sync"] for lv in levels if "sync" in lv]
    sustainable = [
        (c, lv) for c, lv in cont
        if c.get("latency_ms_p99") is not None
        and c["throughput_samples_per_s"]
        >= MIN_SUSTAINED_FRAC * min(c["offered_samples_per_s"],
                                    max(x["throughput_samples_per_s"]
                                        for x, _ in cont))]
    if not sustainable or not sync:
        return {"speedup_vs_sync_at_equal_p99": None,
                "note": "insufficient data"}
    c_best, lv = max(sustainable,
                     key=lambda t: t[0]["throughput_samples_per_s"])
    p99 = c_best["latency_ms_p99"]
    qualifying = [s for s in sync
                  if s.get("latency_ms_p99") is not None
                  and s["latency_ms_p99"] <= p99]
    if not qualifying:
        # sync can't reach this p99 at ANY measured load: report the
        # ratio against its least-loaded point (a lower bound)
        s_best = min(sync, key=lambda s: s.get("latency_ms_p99",
                                               float("inf")))
        note = ("sync p99 exceeds the continuous p99 at every measured "
                "level; ratio vs the least-loaded sync point is a lower "
                "bound")
    else:
        s_best = max(qualifying,
                     key=lambda s: s["throughput_samples_per_s"])
        note = None
    ratio = (c_best["throughput_samples_per_s"]
             / max(s_best["throughput_samples_per_s"], 1e-9))
    out = {
        "speedup_vs_sync_at_equal_p99": round(ratio, 2),
        "continuous": {
            "load_fraction": lv["load_fraction"],
            "throughput_samples_per_s":
                c_best["throughput_samples_per_s"],
            "latency_ms_p99": p99,
            "shed_rate": c_best["shed_rate"],
        },
        "sync_at_equal_p99": {
            "throughput_samples_per_s":
                s_best["throughput_samples_per_s"],
            "latency_ms_p99": s_best.get("latency_ms_p99"),
        },
    }
    if note:
        out["note"] = note
    return out


def _check_slo_invariant(engine) -> int:
    """No served (non-shed) deadline-carrying request finished late."""
    viol = 0
    for r in engine._async_done:
        if r.shed is None and r.deadline is not None \
                and r.t_done > r.deadline:
            viol += 1
    return viol


def run(smoke: bool | None = None):
    from repro.launch import loadgen
    from repro.serving import ServingEngine
    from repro.serving.continuous import SLOConfig

    if smoke is None:
        smoke = os.environ.get("REPRO_LOAD_SMOKE") == "1"
    presets = SMOKE_PRESETS if smoke else FULL_PRESETS
    levels = SMOKE_LEVELS if smoke else FULL_LEVELS
    duration = SMOKE_DURATION_S if smoke else FULL_DURATION_S

    curve = {"levels_are_fractions_of_capacity": list(levels),
             "deadline_ms": DEADLINE_MS, "sizes": SIZES,
             "duration_s": duration, "smoke": bool(smoke),
             "presets": {}}
    slo = SLOConfig(max_queue_samples=64 * MAX_BUCKET)
    slo_violations = 0
    for preset in presets:
        # backend="auto": startup autotunes + calibrates, and the
        # calibration timings seed the admission estimator
        engine = ServingEngine(preset, backend="auto",
                               max_bucket=MAX_BUCKET, n_train=2000)
        engines = {preset: engine}
        capacity = loadgen.measure_capacity(engine,
                                            requests=CAPACITY_REQUESTS)
        tenants = (loadgen.Tenant(name=preset, size=SIZES,
                                  deadline_ms=DEADLINE_MS, preset=preset),)
        entry = {"capacity_samples_per_s": round(capacity, 1),
                 "levels": []}
        for i, frac in enumerate(levels):
            spec = loadgen.LoadSpec(
                rate_rps=frac * capacity / MEAN_SIZE,
                duration_s=duration, seed=1000 + i,
                burst_factor=2.0, burst_every_s=1.0, burst_len_s=0.2,
                tenants=tenants)
            level = loadgen.run_level(engines, spec, mode="both", slo=slo)
            level["load_fraction"] = frac
            entry["levels"].append(level)
            c, s = level["continuous"], level["sync"]
            print(f"{preset} @ {frac:.2f}x: offered "
                  f"{c['offered_samples_per_s']:.0f}/s | continuous "
                  f"{c['throughput_samples_per_s']:.0f}/s "
                  f"p99={c.get('latency_ms_p99')}ms "
                  f"shed={c['shed_rate']:.3f} | sync "
                  f"{s['throughput_samples_per_s']:.0f}/s "
                  f"p99={s.get('latency_ms_p99')}ms", flush=True)
        entry.update(_speedup_at_equal_p99(entry["levels"]))
        slo_violations += _check_slo_invariant(engine)
        curve["presets"][preset] = entry

    if not smoke:
        # multi-tenant mix: sm (latency-critical, higher priority) + md
        # sharing one arrival process, each preset on its own engine
        sm = ServingEngine("dwn-jsc-sm", backend="auto",
                           max_bucket=MAX_BUCKET, n_train=2000)
        md = ServingEngine("dwn-jsc-md", backend="auto",
                           max_bucket=MAX_BUCKET, n_train=2000)
        engines = {"dwn-jsc-sm": sm, "dwn-jsc-md": md}
        cap = {p: loadgen.measure_capacity(e, requests=CAPACITY_REQUESTS)
               for p, e in engines.items()}
        tenants = (
            loadgen.Tenant(name="sm", weight=cap["dwn-jsc-sm"],
                           size=SIZES, deadline_ms=25.0, priority=1,
                           preset="dwn-jsc-sm"),
            loadgen.Tenant(name="md", weight=cap["dwn-jsc-md"],
                           size=SIZES, deadline_ms=100.0, priority=0,
                           preset="dwn-jsc-md"),
        )
        mixed = {"capacity_samples_per_s":
                 {p: round(c, 1) for p, c in cap.items()}, "levels": []}
        for i, frac in enumerate((0.5, 1.0)):
            spec = loadgen.LoadSpec(
                rate_rps=frac * sum(cap.values()) / MEAN_SIZE,
                duration_s=duration, seed=2000 + i, burst_factor=2.0,
                burst_every_s=1.0, burst_len_s=0.2, tenants=tenants)
            level = loadgen.run_level(engines, spec, mode="async",
                                      slo=slo)
            level["load_fraction"] = frac
            mixed["levels"].append(level)
            c = level["continuous"]
            print(f"mixed sm+md @ {frac:.2f}x: "
                  f"{c['throughput_samples_per_s']:.0f}/s "
                  f"p99={c.get('latency_ms_p99')}ms "
                  f"shed={c['shed_rate']:.3f}", flush=True)
        slo_violations += sum(_check_slo_invariant(e)
                              for e in engines.values())
        curve["mixed"] = mixed

    try:
        with open(BENCH_JSON) as fh:
            record = json.load(fh)
    except (OSError, json.JSONDecodeError):
        record = {}
    record["curve"] = curve
    with open(BENCH_JSON, "w") as fh:
        json.dump(record, fh, indent=2)
    print(f"\nwritten {BENCH_JSON.name}: curve over "
          f"{len(curve['presets'])} preset(s) x {len(levels)} levels")

    failures = []
    if slo_violations:
        failures.append(f"SLO invariant violated: {slo_violations} "
                        f"request(s) returned late without shed marking")
    for preset, entry in curve["presets"].items():
        ratio = entry.get("speedup_vs_sync_at_equal_p99")
        if ratio is not None and ratio < SPEEDUP_FLOOR:
            failures.append(
                f"{preset}: continuous/sync at equal p99 = {ratio:.2f}x "
                f"< {SPEEDUP_FLOOR}x floor")
    if failures:
        msg = "; ".join(failures)
        if os.environ.get("LOAD_HARNESS_NO_GATE") == "1":
            print(f"WARNING (gate disabled): {msg}")
        else:
            print(f"ERROR: {msg}")
            raise SystemExit(1)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or None)
