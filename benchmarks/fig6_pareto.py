"""Fig. 6 — Pareto frontier of LUT-based architectures on JSC
(accuracy vs LUTs, log-x ASCII plot + frontier listing)."""

from .common import load_trained, csv_row, Timer


def run():
    import math
    from repro.hw.cost import dwn_hw_report
    from repro.hw.report import PAPER_TABLE2

    points = [(m, a, l) for (m, a, l, *_r) in PAPER_TABLE2
              if not m.startswith("DWN")]
    with Timer() as t:
        for name in ("sm-10", "sm-50", "md-360", "lg-2400"):
            b = load_trained(name)
            ten = dwn_hw_report(b["frozen_ten"], variant="TEN", name=name)
            ft = dwn_hw_report(b["frozen_ft"], variant="PEN+FT", name=name,
                               input_bits=b["ft_bits"])
            points.append((f"DWN-TEN({name})[ours]", 100 * b["float_acc"],
                           ten.total_luts))
            points.append((f"DWN-PEN+FT({name})[ours]", 100 * b["ft_acc"],
                           ft.total_luts))

    # Pareto frontier: maximize acc, minimize LUTs
    frontier = []
    for m, a, l in sorted(points, key=lambda p: p[2]):
        if not frontier or a > frontier[-1][1]:
            frontier.append((m, a, l))
    csv_row("fig6/pareto", t.us,
            "frontier=" + "|".join(m for m, _, _ in frontier))

    print("\nPareto frontier (LUTs ascending):")
    for m, a, l in frontier:
        print(f"  {l:>8d} LUT  {a:5.1f}%  {m}")

    # ASCII scatter
    print("\nacc% vs log10(LUTs):")
    for row_acc in range(78, 60, -2):
        line = [" "] * 72
        for m, a, l in points:
            if row_acc <= a < row_acc + 2:
                x = int((math.log10(max(l, 1)) - 1) / 5 * 70)
                if 0 <= x < 72:
                    line[x] = "D" if "ours" in m else "*"
        print(f"{row_acc:3d} |" + "".join(line))
    print("     " + "-" * 70)
    print("      10       100       1k        10k       100k      1M")
    return frontier


if __name__ == "__main__":
    run()
