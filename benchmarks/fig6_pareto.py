"""Fig. 6 — Pareto frontier of LUT-based architectures on JSC
(accuracy vs LUTs, log-x ASCII plot + frontier listing).

Thin wrapper over ``repro.sweep``: the point assembly lives in
``sweep.artifacts`` (literature rows + our TEN/PEN+FT operating points)
and the frontier rule is ``sweep.results.pareto_front`` — the same
staircase every sweep Pareto view uses, producing the same frontier as
the pre-refactor inline loop.
"""

from .common import load_trained, csv_row, Timer


def run():
    import math
    from repro.sweep.artifacts import PRESETS, literature_points, our_points
    from repro.sweep.results import pareto_front

    points = literature_points()
    with Timer() as t:
        for name in PRESETS:
            points.extend(our_points(load_trained(name), name))

    # Pareto frontier: maximize acc, minimize LUTs
    frontier = pareto_front(points, cost=lambda p: p[2],
                            score=lambda p: p[1])
    csv_row("fig6/pareto", t.us,
            "frontier=" + "|".join(m for m, _, _ in frontier))

    print("\nPareto frontier (LUTs ascending):")
    for m, a, l in frontier:
        print(f"  {l:>8d} LUT  {a:5.1f}%  {m}")

    # ASCII scatter
    print("\nacc% vs log10(LUTs):")
    for row_acc in range(78, 60, -2):
        line = [" "] * 72
        for m, a, l in points:
            if row_acc <= a < row_acc + 2:
                x = int((math.log10(max(l, 1)) - 1) / 5 * 70)
                if 0 <= x < 72:
                    line[x] = "D" if "ours" in m else "*"
        print(f"{row_acc:3d} |" + "".join(line))
    print("     " + "-" * 70)
    print("      10       100       1k        10k       100k      1M")
    return frontier


if __name__ == "__main__":
    run()
