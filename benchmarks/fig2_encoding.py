"""Fig. 2 — distributive vs uniform thermometer encoding of JSC sample 0.

Thin wrapper over ``repro.sweep.artifacts`` (``placement_popcounts`` +
``encoding_mode_accuracy`` — same recipe/seeds as before the sweep
refactor, same numbers).  Prints the two encodings side by side and the
accuracy delta of a small DWN trained under each mode — the paper's
reason for paying the distributive encoder's irregular-comparator cost.
"""

from .common import csv_row, Timer


def run():
    from repro.data.jsc import load_jsc
    from repro.sweep.artifacts import (encoding_mode_accuracy,
                                       placement_popcounts)

    data = load_jsc(8000, 2000)
    pops = placement_popcounts(data, ("distributive", "uniform"))
    print("feature | x value | distributive popcount | uniform popcount")
    for f in range(6):
        print(f"  f{f:02d}   | {data.x_train[0, f]:+.3f} | "
              f"{int(pops['distributive'][f]):4d}/200 | "
              f"{int(pops['uniform'][f]):4d}/200")

    accs = {}
    for mode in ("distributive", "uniform"):
        with Timer() as t:
            accs[mode] = encoding_mode_accuracy(data, "sm-50", mode,
                                                epochs=6)
        csv_row(f"fig2/{mode}", t.us, f"acc={accs[mode]:.4f}")
    print(f"\ndistributive={accs['distributive']:.4f} "
          f"uniform={accs['uniform']:.4f} "
          f"delta={accs['distributive'] - accs['uniform']:+.4f} "
          "(paper: distributive higher)")
    return accs


if __name__ == "__main__":
    run()
