"""Fig. 2 — distributive vs uniform thermometer encoding of JSC sample 0.

Prints the two encodings side by side (ASCII) and the accuracy delta of a
small DWN trained under each mode — the paper's reason for paying the
distributive encoder's irregular-comparator cost.
"""

from .common import csv_row, Timer


def run():
    import numpy as np
    import jax
    from repro.core import JSC_PRESETS, train_dwn, freeze, eval_accuracy_hard
    from repro.core.thermometer import ThermometerSpec, fit_thresholds, encode_np
    from repro.core.warmstart import warmstart_dwn
    from repro.data.jsc import load_jsc
    import dataclasses

    data = load_jsc(8000, 2000)
    spec_d = ThermometerSpec(16, 200, "distributive")
    spec_u = ThermometerSpec(16, 200, "uniform")
    th_d = fit_thresholds(data.x_train, spec_d)
    th_u = fit_thresholds(data.x_train, spec_u)

    x0 = data.x_train[:1]
    bits_d = encode_np(x0, th_d, flatten=False)[0]
    bits_u = encode_np(x0, th_u, flatten=False)[0]
    print("feature | x value | distributive popcount | uniform popcount")
    for f in range(6):
        print(f"  f{f:02d}   | {x0[0, f]:+.3f} | "
              f"{int(bits_d[f].sum()):4d}/200 | {int(bits_u[f].sum()):4d}/200")

    accs = {}
    for mode in ("distributive", "uniform"):
        cfg = dataclasses.replace(JSC_PRESETS["sm-50"], encoding=mode)
        params, buffers = warmstart_dwn(jax.random.PRNGKey(0), cfg,
                                        data.x_train, data.y_train)
        with Timer() as t:
            res = train_dwn(cfg, data, epochs=6, batch=128, lr=1e-3,
                            params=params, buffers=buffers, verbose=False)
        acc = eval_accuracy_hard(freeze(res.params, res.buffers, cfg),
                                 data.x_test, data.y_test)
        accs[mode] = acc
        csv_row(f"fig2/{mode}", t.us, f"acc={acc:.4f}")
    print(f"\ndistributive={accs['distributive']:.4f} "
          f"uniform={accs['uniform']:.4f} "
          f"delta={accs['distributive'] - accs['uniform']:+.4f} "
          "(paper: distributive higher)")
    return accs


if __name__ == "__main__":
    run()
