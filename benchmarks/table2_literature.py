"""Table II — comparison with LUT-based architectures on JSC.

Literature rows are constants from the paper; our DWN-PEN+FT rows come
from the trained models + our cost model.  Reproduces the paper's
qualitative placement: DWN most efficient at the low-accuracy end,
TreeLUT / NeuraLUT-Assemble better in the >=75% band.
"""

from .common import load_trained, csv_row, Timer


def run():
    from repro.hw.cost import dwn_hw_report
    from repro.hw.report import PAPER_TABLE2

    ours = []
    for name in ("sm-10", "sm-50", "md-360", "lg-2400"):
        b = load_trained(name)
        with Timer() as t:
            ft = dwn_hw_report(b["frozen_ft"], variant="PEN+FT", name=name,
                               input_bits=b["ft_bits"])
        ours.append((f"DWN-PEN+FT ({name}) ({b['ft_bits']}-Bit) [ours]",
                     100 * b["ft_acc"], ft.total_luts, ft.total_ffs))
        csv_row(f"table2/{name}", t.us,
                f"acc={b['ft_acc']:.3f};luts={ft.total_luts}")

    rows = [(m, a, l, f) for (m, a, l, f, *_rest) in PAPER_TABLE2]
    rows += ours
    rows.sort(key=lambda r: -r[1])
    print("\n| model | acc % | LUT | FF |")
    print("|---|---|---|---|")
    for m, a, l, f in rows:
        print(f"| {m} | {a:.1f} | {l} | {f} |")
    return rows


if __name__ == "__main__":
    run()
