"""Roofline table from the dry-run artifacts (mandate deliverable g).

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the
dominant term" note.  Emits the markdown EXPERIMENTS.md §Roofline embeds.
"""

import json

from .common import DRYRUN, csv_row


NOTES = {
    ("moe", "train"): "cut expert-capacity waste + overlap FSDP gathers",
    ("moe", "prefill"): "capacity-factor 1.0 + fused dispatch",
    ("moe", "decode"): "shard experts (EP) to stop weight streaming",
    ("dense", "train"): "less remat recompute; fuse CE to cut logits traffic",
    ("dense", "prefill"): "block-triangular flash (skip masked KV chunks)",
    ("dense", "decode"): "batch weight reads are already minimal: cache bf16->int8",
    ("vlm", "train"): "microbatch=8 residuals dominate: offload or seq-shard",
    ("ssm", "train"): "bigger SSD chunk: amortize state IO per chunk",
    ("ssm", "decode"): "state is O(1): bound = params streaming; int8 weights",
    ("hybrid", "decode"): "replicated LRU gates: shard W over model",
    ("encdec", "train"): "encoder is non-causal: drop the causal mask waste",
    ("dwn", "train"): "bit tensor traffic: pack bits / fuse encode+select",
    ("dwn", "prefill"): "prune unused thermometer columns + VMEM fusion",
}


def note_for(arch_family: str, kind: str, bound: str) -> str:
    base = NOTES.get((arch_family, kind), "rebalance sharding")
    if bound == "collective":
        return "hierarchical/overlapped collectives; " + base
    return base


def load_records():
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        recs.append(r)
    return recs


def run():
    from repro.configs import get_arch, SHAPES, DWN_SHAPES
    recs = load_records()
    shapes = {**SHAPES, **DWN_SHAPES}
    print("| cell | chips | bound | compute s | memory s | collective s "
          "| model/HLO flops | note |")
    print("|---|---|---|---|---|---|---|---|")
    worst = []
    for r in recs:
        if r.get("skipped"):
            print(f"| {r['cell']} | - | SKIP | - | - | - | - "
                  f"| {r['reason'][:60]} |")
            continue
        if "error" in r or "roofline" not in r:
            continue
        cfg = get_arch(r["arch"])
        kind = shapes[r["shape"]].kind
        rf = r["roofline"]
        ratio = r.get("useful_flops_ratio", 0)
        print(f"| {r['cell']} | {r['chips']} | {rf['bound']} "
              f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
              f"| {rf['collective_s']:.4f} | {ratio:.3f} "
              f"| {note_for(cfg.family, kind, rf['bound'])} |")
        worst.append((ratio, r["cell"]))
        csv_row(f"roofline/{r['cell']}", 0.0,
                f"bound={rf['bound']};ratio={ratio:.3f}")
    worst.sort()
    if worst:
        print("\nworst useful-flops ratios (hillclimb candidates):")
        for ratio, cell in worst[:5]:
            print(f"  {ratio:.3f}  {cell}")
    return recs


if __name__ == "__main__":
    run()
