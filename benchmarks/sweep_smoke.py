"""Sweep smoke — the tiny encoding grid through the full sweep pipeline.

Runs ``repro.sweep`` end-to-end (accuracy + hardware + fused-kernel axes;
the serving axis is covered separately by ``serve_bench``) on the 6-point
tiny grid and prints the result table.  Asserts the two sweep invariants
that the paper-tolerance tests also pin down: TEN rows within tolerance
and encoder LUTs monotone in the PEN input width.
"""

from .common import csv_row, Timer


def run():
    from repro.sweep import SweepSettings, run_grid
    from repro.sweep.artifacts import TABLE1_TEN_TOLERANCE

    settings = SweepSettings(n_train=1000, n_test=500, serve=False,
                             kernel_batch=64, kernel_iters=1)
    with Timer() as t:
        result = run_grid("tiny", settings, cache_dir=None)
    print(result.table())
    for r in result.points:
        csv_row(f"sweep/{r.point.label}", t.us / len(result.points),
                f"luts={r.total_luts};acc={r.accuracy};"
                f"kernel_us={r.kernel_us}")

    by = {r.point.label: r for r in result.points}
    for preset in ("sm-10", "sm-50"):
        ten = by[f"{preset}/TEN/T200/distributive"]
        err = abs(ten.total_luts - ten.paper_luts) / ten.paper_luts
        assert err <= TABLE1_TEN_TOLERANCE[preset], (preset, err)
        pen4 = by[f"{preset}/PEN@4b/T200/distributive"]
        pen9 = by[f"{preset}/PEN@9b/T200/distributive"]
        assert pen4.luts["encoder"] < pen9.luts["encoder"], preset
    return result


if __name__ == "__main__":
    run()
