"""Shared helpers for the paper-table benchmarks."""

import pickle
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

MODELS = ROOT / "results" / "dwn_models"
DRYRUN = ROOT / "results" / "dryrun"


def load_trained(name: str):
    """Load a trained DWN bundle produced by examples/train_jsc_dwn.py;
    trains a quick fallback version if the pipeline has not run yet."""
    f = MODELS / f"{name}.pkl"
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    # fallback: quick training so benchmarks stay runnable stand-alone
    import jax
    from repro.core import (JSC_PRESETS, train_dwn, freeze,
                            eval_accuracy_hard, ptq_bitwidth_search,
                            finetune_bitwidth_search)
    from repro.core.warmstart import warmstart_dwn
    from repro.data.jsc import load_jsc
    data = load_jsc(8000, 2000)
    cfg = JSC_PRESETS[name]
    params = buffers = None
    if name in ("sm-10", "sm-50"):
        params, buffers = warmstart_dwn(jax.random.PRNGKey(0), cfg,
                                        data.x_train, data.y_train)
    res = train_dwn(cfg, data, epochs=4, batch=128, lr=1e-3,
                    params=params, buffers=buffers, verbose=False)
    acc = eval_accuracy_hard(freeze(res.params, res.buffers, cfg),
                             data.x_test, data.y_test)
    ptq = ptq_bitwidth_search(res.params, res.buffers, cfg, data,
                              baseline_acc=acc, verbose=False)
    ft = finetune_bitwidth_search(res.params, res.buffers, cfg, data,
                                  baseline_acc=acc, start_frac=ptq.frac_bits,
                                  epochs=2, verbose=False)
    ft_params = ft.result.params if ft.result else res.params
    ft_buffers = ft.result.buffers if ft.result else res.buffers
    return {
        "name": name, "float_acc": acc,
        "pen_bits": ptq.total_bits, "pen_acc": ptq.accuracy,
        "pen_sweep": ptq.sweep, "ft_bits": ft.total_bits,
        "ft_acc": ft.accuracy, "ft_sweep": ft.sweep,
        "frozen_ten": freeze(res.params, res.buffers, cfg),
        "frozen_pen": freeze(res.params, res.buffers, cfg,
                             input_frac_bits=ptq.frac_bits),
        "frozen_ft": freeze(ft_params, ft_buffers, cfg,
                            input_frac_bits=ft.frac_bits),
        "_fallback": True,
    }


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
