"""Table I — hardware comparison of DWN-TEN and DWN-PEN+FT per model size.

Prints our generator's LUT/FF/delay next to the paper's Vivado numbers
with % error, plus the A x D product.  The TEN column exercises only the
LUT layer + classification logic (what [13] reported); PEN+FT adds the
thermometer encoders at the fine-tuned input bit-width.
"""

from .common import load_trained, csv_row, Timer


def run():
    from repro.hw.cost import dwn_hw_report
    from repro.hw.report import PAPER_TABLE1

    rows = []
    for name in ("sm-10", "sm-50", "md-360", "lg-2400"):
        b = load_trained(name)
        with Timer() as t:
            rep_ten = dwn_hw_report(b["frozen_ten"], variant="TEN",
                                    name=name)
            rep_ft = dwn_hw_report(b["frozen_ft"], variant="PEN+FT",
                                   name=name, input_bits=b["ft_bits"])
        for variant, rep in (("TEN", rep_ten), ("PEN+FT", rep_ft)):
            paper = PAPER_TABLE1.get((name, variant), {})
            err = (100.0 * (rep.total_luts - paper["luts"]) / paper["luts"]
                   if paper else float("nan"))
            rows.append((name, variant, rep, paper, err))
            csv_row(f"table1/{name}/{variant}", t.us,
                    f"luts={rep.total_luts};ffs={rep.total_ffs};"
                    f"paper_luts={paper.get('luts')};err_pct={err:.1f}")

    print("\n| model | variant | bits | LUT (ours) | LUT (paper) | err% "
          "| FF (ours) | FF (paper) | delay ns (est) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for name, variant, rep, paper, err in rows:
        print(f"| {name} | {variant} | {rep.input_bits or '-'} "
              f"| {rep.total_luts} | {paper.get('luts', '-')} | {err:+.1f} "
              f"| {rep.total_ffs} | {paper.get('ffs', '-')} "
              f"| {rep.delay_ns:.2f} |")
    return rows


if __name__ == "__main__":
    run()
