"""Table I — hardware comparison of DWN-TEN and DWN-PEN+FT per model size.

Thin wrapper over ``repro.sweep.artifacts.table1_model_rows`` (the row
logic moved there in the sweep refactor — same calls, same numbers).
Prints our generator's LUT/FF/delay next to the paper's Vivado numbers
with % error, plus the A x D product.
"""

from .common import load_trained, csv_row, Timer


def run():
    from repro.sweep.artifacts import PRESETS, table1_model_rows

    rows = []
    for name in PRESETS:
        b = load_trained(name)
        with Timer() as t:
            model_rows = table1_model_rows(b, name)
        for variant, rep, paper, err in model_rows:
            rows.append((name, variant, rep, paper, err))
            csv_row(f"table1/{name}/{variant}", t.us,
                    f"luts={rep.total_luts};ffs={rep.total_ffs};"
                    f"paper_luts={paper.get('luts')};err_pct={err:.1f}")

    print("\n| model | variant | bits | LUT (ours) | LUT (paper) | err% "
          "| FF (ours) | FF (paper) | delay ns (est) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for name, variant, rep, paper, err in rows:
        print(f"| {name} | {variant} | {rep.input_bits or '-'} "
              f"| {rep.total_luts} | {paper.get('luts', '-')} | {err:+.1f} "
              f"| {rep.total_ffs} | {paper.get('ffs', '-')} "
              f"| {rep.delay_ns:.2f} |")
    return rows


if __name__ == "__main__":
    run()
