"""Fig. 5 — component LUT breakdown of DWN-PEN+FT vs input bit-width.

Thin wrapper over ``repro.sweep.artifacts.breakdown_rows`` (the per-width
``dwn_hw_report`` loop moved there in the sweep refactor — same numbers).
Reproduces the paper's finding: encoders dominate small models at every
bit-width; for lg-2400 the LUT layer + popcount take over below ~10 bits.
"""

from .common import load_trained, csv_row, Timer


def run():
    from repro.sweep.artifacts import PRESETS, breakdown_rows

    out = {}
    for name in PRESETS:
        b = load_trained(name)
        with Timer() as t:
            rows = breakdown_rows(b["frozen_ft"], name)
        out[name] = rows
        csv_row(f"fig5/{name}", t.us,
                f"enc_frac@6b={rows[0][1]['encoder'] / rows[0][2]:.2f};"
                f"enc_frac@12b={rows[-1][1]['encoder'] / rows[-1][2]:.2f}")

    print("\n| model | bits | encoder | lut_layer | popcount | argmax "
          "| enc % |")
    print("|---|---|---|---|---|---|---|")
    for name, rows in out.items():
        for bits, luts, total in rows:
            print(f"| {name} | {bits} | {luts['encoder']} "
                  f"| {luts['lut_layer']} | {luts['popcount']} "
                  f"| {luts['argmax']} | {100 * luts['encoder'] / total:.0f}% |")

    # paper claims: encoder dominates the small models at every width and
    # its *share* falls with model size (Fig. 5's shape).  The absolute
    # lg-2400 crossover point depends on the trained mapping's threshold
    # dedup, so the assertion checks the scaling trend.
    for name in ("sm-10", "sm-50"):
        for bits, luts, total in out[name]:
            assert luts["encoder"] >= 0.4 * total, (name, bits)
    for i, bits in enumerate(b for b, _, _ in out["sm-10"]):
        enc_sm = out["sm-10"][i][1]["encoder"] / out["sm-10"][i][2]
        enc_lg = out["lg-2400"][i][1]["encoder"] / out["lg-2400"][i][2]
        assert enc_lg < enc_sm, (bits, enc_lg, enc_sm)
    return out


if __name__ == "__main__":
    run()
