"""Cosim smoke — RTL-vs-oracle verification timing on the sm preset.

Emits the accelerator for ``dwn-jsc-sm`` (TEN and PEN), runs the
pure-Python netlist evaluator over real JSC vectors, and asserts
bit-exact agreement with ``apply_hard_packed`` (argmax, winning count,
per-class counts).  The full sm/md/lg x TEN/PEN gate runs as its own CI
step via ``python -m repro.hw.cosim``; this row keeps a wall-clock
number for the verification itself in the benchmark record.
"""

from .common import csv_row, Timer


def run():
    import dataclasses

    from repro.data.jsc import load_jsc
    from repro.dwn import DWNArtifact
    from repro.dwn.spec import get_spec
    from repro.hw.cosim import simulator_available

    data = load_jsc(1000, 256, seed=0)
    base = get_spec("dwn-jsc-sm")
    art_ten = DWNArtifact(base).fit(data.x_train, seed=0)
    state = (art_ten.params, art_ten.buffers)
    sim = simulator_available() or "none (python evaluator only)"
    print(f"simulator: {sim}")

    reports = []
    for variant in ("TEN", "PEN"):
        spec = base if variant == "TEN" else dataclasses.replace(
            base, variant="PEN", input_bits=9)
        art = DWNArtifact(spec).adopt(*state, note="bench").freeze()
        with Timer() as t:
            rep = art.verify_rtl(data.x_test[:256], backend="python")
        assert rep.counts_checked and rep.n_vectors == 256
        csv_row(f"cosim/{spec.label}", t.us,
                f"vectors={rep.n_vectors};backends={'+'.join(rep.backends)}")
        reports.append(rep)

    print("| spec | vectors | backends | bit-exact |")
    print("|---|---|---|---|")
    for rep in reports:
        print(f"| {rep.spec} | {rep.n_vectors} | "
              f"{'+'.join(rep.backends)} | yes |")
    return reports


if __name__ == "__main__":
    run()
