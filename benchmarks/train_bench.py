"""Training benchmark: scan-compiled engine vs the pre-PR reference loop.

Trains the md JSC preset (md-360) twice with identical protocol (same
seed, batch, epochs — therefore the same minibatch order and schedule
step count): once through the frozen pre-PR python-per-minibatch loop
(``repro.training.reference``) and once through the scan-compiled engine
(``repro.training.engine``).  Epochs of the two engines are
**interleaved** (ref epoch e, scan epoch e, ...) so both see the same
machine conditions, and the headline speedup is the median of per-epoch
wall-clock ratios over the steady-state epochs (epoch 0 carries each
engine's compile and is reported separately).

An epoch's wall-clock includes its end-of-epoch eval, exactly like the
``train_dwn`` history ``sec`` field: the reference pays its fresh-jit
eval per epoch (the pre-PR behavior), the scan engine its cached
evaluator.  Units: seconds per epoch; ``steps_per_s`` counts optimizer
steps.

Also measured: the vmapped multi-seed batch trainer
(``train_dwn_batch``) against sequential scan runs, the loss/param
trajectory parity between the engines at fixed seed, and the resilient
parallel sweep executor against the serial in-process grid runner on the
tiny grid (``sweep_executor`` row — parallel-vs-serial wall-clock).

Writes ``BENCH_train.json`` at the repo root (one record per run,
overwritten) — the training-side companion of ``BENCH_kernels.json`` /
``BENCH_serve.json``.
"""

import json
import os
import time

import numpy as np

from .common import csv_row, ROOT

BENCH_JSON = ROOT / "BENCH_train.json"

PRESET = "md-360"
N_TRAIN, N_TEST = 4000, 1000
BATCH = 128
# timed epochs (after the compile epoch); CI runs the 2-epoch shape
EPOCHS = int(os.environ.get("TRAIN_BENCH_EPOCHS", "4"))
SEEDS = (0, 1)        # batch-trainer axis
SWEEP_WORKERS = int(os.environ.get("TRAIN_BENCH_SWEEP_WORKERS", "2"))


def bench_sweep_executor(workers: int = SWEEP_WORKERS) -> dict:
    """Tiny sweep grid, serial in-process vs the resilient parallel
    executor (fresh caches for both, so each run computes every point).

    On a 2-core CPU the parallel win is modest — worker spawn + per-process
    JAX compile is amortized over only 6 points — but the row pins the
    overhead so regressions in executor dispatch show up; on multi-core
    hosts it approaches the worker count.
    """
    import shutil
    import tempfile

    from repro.sweep import (ExecutorSettings, SweepSettings, run_grid,
                             run_grid_parallel)

    settings = SweepSettings(n_train=512, n_test=256, accuracy=False,
                             kernel=False, serve=False)
    tmp = tempfile.mkdtemp(prefix="sweep_exec_bench_")
    try:
        t0 = time.perf_counter()
        serial = run_grid("tiny", settings, cache_dir=f"{tmp}/serial")
        serial_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        par = run_grid_parallel("tiny", settings,
                                cache_dir=f"{tmp}/parallel",
                                executor=ExecutorSettings(workers=workers))
        parallel_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    assert len(par.points) == len(serial.points)
    assert par.executor["failed"] == []
    csv_row("train/sweep_executor/tiny", parallel_s * 1e6,
            f"serial_s={serial_s:.2f};parallel_s={parallel_s:.2f};"
            f"workers={workers}")
    return {
        "grid": "tiny", "points": len(par.points), "workers": workers,
        "units": "wall-clock seconds for the full grid, fresh cache",
        "serial_wall_s": round(serial_s, 3),
        "parallel_wall_s": round(parallel_s, 3),
        "speedup": round(serial_s / parallel_s, 2),
        "executor": {k: par.executor[k]
                     for k in ("computed", "restarts", "worker_deaths",
                               "stragglers_redispatched",
                               "workers_spawned")},
    }


def run(epochs: int = EPOCHS):
    import jax
    from repro.core import JSC_PRESETS
    from repro.data.jsc import load_jsc
    from repro.training import ReferenceTrainer, ScanTrainer, train_dwn_batch

    data = load_jsc(N_TRAIN, N_TEST, seed=0)
    cfg = JSC_PRESETS[PRESET]

    ref = ReferenceTrainer(cfg, data, batch=BATCH, seed=0)
    scan = ScanTrainer(cfg, data, batch=BATCH, seed=0)

    def ref_epoch():
        t0 = time.perf_counter()
        losses = ref.run_epoch()
        ref.evaluate()                      # pre-PR: fresh jit per epoch
        return np.asarray(losses), time.perf_counter() - t0

    def scan_epoch():
        t0 = time.perf_counter()
        losses = scan.run_epochs(1)[0]
        scan.evaluate()                     # cached evaluator
        return losses, time.perf_counter() - t0

    ref_s, scan_s = [], []
    loss_diff = 0.0
    for e in range(epochs + 1):             # epoch 0 = compile epoch
        rl, rt = ref_epoch()
        sl, st = scan_epoch()
        loss_diff = max(loss_diff, float(np.abs(rl - sl).max()))
        if e == 0:
            compile_s = {"reference": round(rt, 3), "scan": round(st, 3)}
        else:
            ref_s.append(rt)
            scan_s.append(st)
            csv_row(f"train/{PRESET}/epoch{e}", st * 1e6,
                    f"ref_s={rt:.2f};scan_s={st:.2f};x={rt / st:.2f}")

    ratios = [r / s for r, s in zip(ref_s, scan_s)]
    speedup = float(np.median(ratios))
    steps = scan.steps_per_epoch

    # trajectory parity on params too (scores move by ~1e-6 from the
    # reassociated-but-equal backward; tables/bits stay bit-identical)
    pdiff = jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a) - np.asarray(b)).max()),
        ref.params, scan.params)

    # vmapped multi-seed batch trainer vs sequential scan runs
    t0 = time.perf_counter()
    out = train_dwn_batch(cfg, data, epochs=2, seeds=SEEDS, batch=BATCH,
                          eval_final=False)
    t_seq = 0.0
    for s in SEEDS:
        t1 = time.perf_counter()
        tr = ScanTrainer(cfg, data, batch=BATCH, seed=s)
        tr.run_epochs(2)
        t_seq += time.perf_counter() - t1

    record = {
        "preset": PRESET,
        "note": "speedup is hardware-dependent: the scan engine removes "
                "the x_soft einsum, the variadic-argmax lowering, two "
                "Adam memory passes, per-batch re-encode, per-step "
                "dispatch + float(loss) syncs, and per-epoch eval "
                "recompiles.  On a 2-core CPU the remaining step sits at "
                "the memory-bandwidth floor of the (m*n*C) score-tree "
                "passes shared by both engines (~2x there); on "
                "accelerator backends, where dispatch/sync and the "
                "eliminated GEMM dominate, the gap is larger.",
        "protocol": {"n_train": N_TRAIN, "n_test": N_TEST, "batch": BATCH,
                     "epochs": epochs, "seed": 0,
                     "steps_per_epoch": steps},
        "units": {"epoch_s": "wall-clock seconds per epoch incl. its "
                             "end-of-epoch eval; median over interleaved "
                             "steady-state epochs",
                  "steps_per_s": "optimizer steps per second"},
        "reference_loop": {
            "epoch_s": round(float(np.median(ref_s)), 3),
            "epoch_s_all": [round(t, 3) for t in ref_s],
            "steps_per_s": round(steps / float(np.median(ref_s)), 1),
            "host_syncs_per_epoch": steps + 1,   # float(loss)/step + eval
        },
        "scan_engine": {
            "epoch_s": round(float(np.median(scan_s)), 3),
            "epoch_s_all": [round(t, 3) for t in scan_s],
            "steps_per_s": round(steps / float(np.median(scan_s)), 1),
            "host_syncs_per_epoch": 1,           # losses fetched per epoch
        },
        "compile_epoch_s": compile_s,
        "speedup_epoch_wallclock": round(speedup, 2),
        "speedup_per_epoch": [round(r, 2) for r in ratios],
        "parity": {"max_step_loss_diff": loss_diff,
                   "max_param_diff": pdiff},
        "batch_trainer": {
            "seeds": list(SEEDS), "epochs": 2,
            "vmapped_wall_s": round(out.wall_s, 3),
            "sequential_wall_s": round(t_seq, 3),
            "speedup": round(t_seq / out.wall_s, 2),
            "data_parallel": out.data_parallel,
        },
        "sweep_executor": bench_sweep_executor(),
    }
    with open(BENCH_JSON, "w") as fh:
        json.dump(record, fh, indent=2)
    print(f"\nwritten {BENCH_JSON.name}: {PRESET} "
          f"ref {record['reference_loop']['epoch_s']}s/epoch vs scan "
          f"{record['scan_engine']['epoch_s']}s/epoch -> "
          f"{record['speedup_epoch_wallclock']}x "
          f"(parity max loss diff {loss_diff:.2e})")
    return record


if __name__ == "__main__":
    run()
