"""Table III — DWN variants (TEN / PEN / PEN+FT): accuracy, LUTs, bit-width.

The paper's claims under test:
  * PTQ alone (PEN) needs wider inputs than PTQ + fine-tuning (PEN+FT);
  * fine-tuning narrows the PEN/TEN LUT gap (sm-10: 5.30x -> 3.20x;
    lg-2400: 3.68x -> 1.41x in the paper);
  * accuracy is preserved through the pipeline.
"""

from .common import load_trained, csv_row, Timer


def run():
    from repro.hw.cost import dwn_hw_report
    from repro.hw.report import PAPER_TABLE3

    print("| model | FT acc | FT LUTs (ours) | FT bits | PEN bits | "
          "TEN LUTs | PEN+FT/TEN (ours) | (paper) |")
    print("|---|---|---|---|---|---|---|---|")
    rows = []
    for name in ("sm-10", "sm-50", "md-360", "lg-2400"):
        b = load_trained(name)
        with Timer() as t:
            ten = dwn_hw_report(b["frozen_ten"], variant="TEN", name=name)
            pen = dwn_hw_report(b["frozen_pen"], variant="PEN", name=name,
                                input_bits=b["pen_bits"])
            ft = dwn_hw_report(b["frozen_ft"], variant="PEN+FT", name=name,
                               input_bits=b["ft_bits"])
        ratio = ft.total_luts / max(ten.total_luts, 1)
        p = PAPER_TABLE3[name]
        paper_ratio = p["ft_luts"] / p["ten_luts"]
        rows.append((name, b, ten, pen, ft, ratio, paper_ratio))
        print(f"| {name} | {b['ft_acc']:.3f} | {ft.total_luts} "
              f"| {b['ft_bits']} | {b['pen_bits']} | {ten.total_luts} "
              f"| {ratio:.2f}x | {paper_ratio:.2f}x |")
        csv_row(f"table3/{name}", t.us,
                f"ft_bits={b['ft_bits']};pen_bits={b['pen_bits']};"
                f"ratio={ratio:.2f};paper_ratio={paper_ratio:.2f}")

    # claims: FT bits <= PEN bits; overhead ratio shrinks with model size
    for name, b, ten, pen, ft, ratio, pr in rows:
        assert b["ft_bits"] <= b["pen_bits"], (name, "FT must not widen")
    if len(rows) >= 2:
        assert rows[-1][5] <= rows[0][5] + 1e-9, \
            "encoder overhead ratio should shrink for larger models"
    return rows


if __name__ == "__main__":
    run()
